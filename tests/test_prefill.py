"""Fused chunked prefill vs the per-op scan-of-decode_step oracle.

The contract under test (docs/kernels.md §fused chunked prefill): the
fused path — chunk-shaped matmuls + the masked on-chip WKV sequence
kernel, packed Δ-PoT weights decoded in-kernel — is BIT-IDENTICAL to a
`lax.scan` of `decode_step` with the engine's per-step masked state
commits, for fp and packed weights, rwkv4 and rwkv6, hw LUT numerics,
and any per-slot PREFIX validity mask (partial chunks, empty lanes).

Both sides compile with defined rounding semantics
(`kernels.common.exact_jit` — `xla_allow_excess_precision=False`), the
property that makes differently-structured programs with the same
per-op math bitwise comparable; the serving engine compiles its two
prefill programs the same way.

Engine-level: `ServingEngine(fused_prefill=True)` streams the exact
greedy tokens of the per-op engine through admission, ragged prompts,
chunk-boundary splits, mid-prefill cancellation, and slot reuse — plus
the packed path never unpacks weights in its trace (jaxpr inspection).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.quant.serving import pack_params
from repro.kernels.common import exact_jit
from repro.models.registry import get_model
from repro.serving import ServingEngine

ARCHS = ["rwkv4-169m", "rwkv6-7b"]
B, C = 4, 6
# per-slot prefix masks: full, partial, empty (a decode/free lane), single
PREFIX_LENS = (C, 3, 0, 1)


def _random_state(model, rng, batch=B, dtype=jnp.bfloat16):
    state = model.init_decode_state(batch, 0, dtype)

    def fill(leaf):
        vals = rng.normal(size=leaf.shape).astype(np.float32)
        if np.all(np.asarray(leaf, np.float32) < -1e30):  # wkv_o running max
            vals = vals - 1.0
        return jnp.asarray(vals, leaf.dtype)

    return jax.tree_util.tree_map(fill, state)


def _prefix_valid(lens, cols=C):
    valid = np.zeros((len(lens), cols), bool)
    for i, n in enumerate(lens):
        valid[i, :n] = True
    return jnp.asarray(valid)


def _assert_bitwise(tree_a, tree_b):
    la = jax.tree_util.tree_leaves(tree_a)
    lb = jax.tree_util.tree_leaves(tree_b)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def oracle_prefill(model, params, state, tokens, valid, *,
                   quantized=False, hw=False):
    """The engine's per-op prefill semantics: scan `decode_step` over the
    chunk, committing state only where `valid` — through the SAME
    `masked_state_commit` / `maybe_unpack` the plan's programs use
    (repro.serving.plan), so the masking semantics exist in exactly one
    place and the oracle can never drift from the engine."""
    from repro.serving.plan import masked_state_commit, maybe_unpack
    axes = model.decode_state_batch_axes()
    masked = lambda new, old, mask: masked_state_commit(new, old, mask,
                                                        axes)
    p = maybe_unpack(params, quantized)
    if hw:
        step = lambda pp, s, t: model.module.decode_step(
            pp, s, t, jnp.int32(0), model.cfg, hw=True)
    else:
        step = lambda pp, s, t: model.decode_step(pp, s, t, jnp.int32(0))

    def body(carry, xs):
        st, last = carry
        tok, ok = xs
        logits, stepped = step(p, st, tok[:, None])
        return (masked(stepped, st, ok),
                jnp.where(ok[:, None, None], logits, last)), None

    last0 = jnp.zeros((tokens.shape[0], 1, model.cfg.vocab),
                      jnp.dtype(model.cfg.dtype))
    (st, last), _ = jax.lax.scan(body, (state, last0), (tokens.T, valid.T))
    return st, last


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_chunk_bit_parity(arch, quantized, rng):
    """THE tentpole claim: fused chunked prefill == masked scan of
    decode_step, bit for bit — states AND last-valid logits — over full,
    partial, empty and single-token prefix masks, from random (non-fresh)
    recurrent states."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    if quantized:
        params = pack_params(params)
    state = _random_state(model, rng)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, C)),
                         jnp.int32)
    valid = _prefix_valid(PREFIX_LENS)
    s1, l1 = exact_jit(lambda p, s: oracle_prefill(
        model, p, s, tokens, valid, quantized=quantized))(params, state)
    prep = model.prepare_prefill_params(params) if quantized else params
    s2, l2 = exact_jit(lambda p, s: model.prefill_chunk(
        p, s, tokens, valid))(prep, state)
    _assert_bitwise(s1, s2)
    _assert_bitwise(l1, l2)


def test_prefill_chunk_hw_numerics_parity(rng):
    """The paper's LUT/PWL numerics compose with the fused prefill: the
    EXP/DIV tables ride into the WKV kernel as operands, and the A9
    activation fake-quant is scoped per token position — same bits as
    scanning decode_step(hw=True)."""
    from repro.models import rwkv4
    model = get_model("rwkv4-169m", smoke=True)
    params = model.cast_params(model.init_params(jax.random.PRNGKey(0)))
    state = _random_state(model, rng)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, C)),
                         jnp.int32)
    valid = _prefix_valid(PREFIX_LENS)
    s1, l1 = exact_jit(lambda p, s: oracle_prefill(
        model, p, s, tokens, valid, hw=True))(params, state)
    s2, l2 = exact_jit(lambda p, s: rwkv4.prefill_chunk(
        p, s, tokens, valid, jnp.int32(0), model.cfg, hw=True))(
            params, state)
    _assert_bitwise(s1, s2)
    _assert_bitwise(l1, l2)


def test_chunk_matmul_packed_equals_unpack(rng):
    """`chunk_matmul` on a packed leaf == `x @ unpack_leaf(leaf).astype`
    exactly: the kernel body calls the SAME unpack_leaf, tiles never split
    the contraction."""
    from repro.core.quant.serving import unpack_leaf
    from repro.kernels.fused_prefill import chunk_matmul
    from repro.core.quant.delta_pot import FORMAT_W8, dpot_pack_int8, \
        dpot_quantize
    w = jnp.asarray(rng.normal(size=(48, 80)), jnp.float32)
    q = dpot_quantize(w, FORMAT_W8, axis=-1)
    leaf = {"packed": dpot_pack_int8(q), "scale": q.scale.astype(jnp.float32)}
    x = jnp.asarray(rng.normal(size=(3, 5, 48)), jnp.bfloat16)
    got = exact_jit(lambda x, l: chunk_matmul(x, l, jnp.bfloat16))(x, leaf)
    want = exact_jit(
        lambda x, l: x @ unpack_leaf(l).astype(jnp.bfloat16))(x, leaf)
    _assert_bitwise(want, got)


def test_shifted_prev_prefix_semantics():
    """Position t sees seq_{t-1} inside the prefix, the LAST valid entry
    after it (the oracle's frozen carry), and `first` at t=0 / empty."""
    from repro.kernels.fused_prefill import shifted_prev
    seq = jnp.arange(1, 5, dtype=jnp.float32).reshape(1, 4, 1)
    seq = jnp.concatenate([seq, seq * 10], 0)          # (2, 4, 1)
    first = jnp.asarray([[100.0], [200.0]])
    valid = _prefix_valid((2, 0), cols=4)
    out = np.asarray(shifted_prev(seq, first, valid))[..., 0]
    np.testing.assert_array_equal(out[0], [100.0, 1.0, 2.0, 2.0])
    np.testing.assert_array_equal(out[1], [200.0] * 4)


# ---------------------------------------------------------------------------
# No-unpack-in-trace: jaxpr inspection of the packed prefill program
# ---------------------------------------------------------------------------


def _outside_kernel_primitives(jaxpr, acc):
    """Primitive names appearing OUTSIDE pallas_call kernels (recursing
    into scan/cond bodies but NOT into kernel jaxprs)."""
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        if eqn.primitive.name == "pallas_call":
            continue
        for v in eqn.params.values():
            vals = v if isinstance(v, (list, tuple)) else [v]
            for e in vals:
                if isinstance(e, jax.core.ClosedJaxpr):
                    _outside_kernel_primitives(e.jaxpr, acc)
                elif isinstance(e, jax.core.Jaxpr):
                    _outside_kernel_primitives(e, acc)
    return acc


def _pallas_consumes_uint8(jaxpr):
    found = [False]

    def walk(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call" and any(
                    getattr(v.aval, "dtype", None) == jnp.uint8
                    for v in eqn.invars):
                found[0] = True
            for v in eqn.params.values():
                vals = v if isinstance(v, (list, tuple)) else [v]
                for e in vals:
                    if isinstance(e, jax.core.ClosedJaxpr):
                        walk(e.jaxpr)
                    elif isinstance(e, jax.core.Jaxpr):
                        walk(e)
    walk(jaxpr)
    return found[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_packed_prefill_never_unpacks_in_trace(arch):
    """THE bandwidth claim: with packed Δ-PoT weights the fused prefill
    trace contains NO weight decode outside a Pallas kernel — the decode's
    signature `exp2` appears only inside kernels, and the uint8 code
    planes are consumed by pallas_call directly.  The per-op oracle, by
    contrast, unpacks in-trace (detector sanity check)."""
    model = get_model(arch, smoke=True)
    packed = pack_params(model.init_params(jax.random.PRNGKey(0)))
    prep = model.prepare_prefill_params(packed)
    state = model.init_decode_state(B, 0, jnp.bfloat16)
    tokens = jnp.zeros((B, C), jnp.int32)
    valid = jnp.ones((B, C), bool)
    jx = jax.make_jaxpr(lambda p, s: model.prefill_chunk(
        p, s, tokens, valid))(prep, state)
    outside = _outside_kernel_primitives(jx.jaxpr, set())
    assert "exp2" not in outside, (
        "packed Δ-PoT decode leaked out of the kernels into the prefill "
        "trace")
    assert _pallas_consumes_uint8(jx.jaxpr)
    # detector sanity: the per-op oracle DOES decode in-trace
    jx_oracle = jax.make_jaxpr(lambda p, s: oracle_prefill(
        model, p, s, tokens, valid, quantized=True))(packed, state)
    assert "exp2" in _outside_kernel_primitives(jx_oracle.jaxpr, set())


# ---------------------------------------------------------------------------
# Engine-level equivalence + prefill edge cases
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def rwkv4():
    model = get_model("rwkv4-169m", smoke=True)
    return model, model.init_params(jax.random.PRNGKey(0))


def _engine(model, params, *, fused_prefill, max_batch=3, chunk=4, **kw):
    return ServingEngine(model, params=params, max_batch=max_batch,
                         prefill_chunk=chunk, fused_prefill=fused_prefill,
                         **kw)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("arch", ARCHS)
def test_engine_greedy_equivalence(arch, quantized):
    """End-to-end: the fused-prefill engine streams the exact token
    sequences of the per-op engine — prompts shorter than one chunk (1),
    exactly one chunk (4), a non-multiple of the chunk (9, 17), through
    admission, chunked prefill, masked decode and retirement."""
    model = get_model(arch, smoke=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
               for n in (1, 4, 9, 17)]

    def run(fused):
        eng = _engine(model, params, fused_prefill=fused,
                      quantized=quantized)
        handles = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run()
        assert eng.trace_counts == {"decode": 1, "prefill": 1}
        return [h.tokens for h in handles]

    assert run(False) == run(True)


def test_engine_cancel_mid_prefill(rwkv4):
    """A request cancelled MID-PREFILL frees its slot; the next admission
    resets the lane via the fresh mask.  Fused and per-op engines agree on
    every surviving request's tokens."""
    model, params = rwkv4
    rng = np.random.default_rng(3)
    long_prompt = rng.integers(0, model.cfg.vocab, size=30).tolist()
    others = [rng.integers(0, model.cfg.vocab, size=n).tolist()
              for n in (5, 11)]

    def run(fused):
        eng = _engine(model, params, fused_prefill=fused, max_batch=1)
        h_long = eng.submit(long_prompt, max_new_tokens=8)
        hs = [eng.submit(p, max_new_tokens=4) for p in others]
        eng.step()                    # absorbs one 4-token chunk of 30
        assert not h_long.done
        assert eng.cancel(h_long)     # slot freed with partial state
        eng.run()
        assert all(h.done for h in hs)
        return [h.tokens for h in hs]

    assert run(False) == run(True)


def test_engine_slot_reuse_after_retire(rwkv4):
    """A slot freed by retirement and re-admitted (max_batch=1 forces
    immediate reuse) must not leak the previous request's state into the
    next — the fresh-lane reset inside the prefill call covers fused and
    per-op identically."""
    model, params = rwkv4
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, model.cfg.vocab, size=n).tolist()
               for n in (7, 7, 3)]

    def run(fused):
        eng = _engine(model, params, fused_prefill=fused, max_batch=1)
        hs = [eng.submit(p, max_new_tokens=3) for p in prompts]
        eng.run()
        return [h.tokens for h in hs]

    from repro.launch.serve import sequential_decode
    toks = run(True)
    assert toks == run(False)
    # and both equal decoding each request alone (no cross-request leak)
    for p, t in zip(prompts, toks):
        assert t == sequential_decode(model, params, p, 3)


def test_engine_temperature_sampling_equivalence(rwkv4):
    """Seeded Gumbel sampling is bit-stable across prefill modes (the
    batched sampler draws from each slot's own RNG stream)."""
    model, params = rwkv4

    def run(fused):
        eng = _engine(model, params, fused_prefill=fused, max_batch=2)
        h1 = eng.submit([1, 2, 3, 4, 5], max_new_tokens=6,
                        temperature=0.9, seed=13)
        h2 = eng.submit([7, 8], max_new_tokens=6, temperature=0.7, seed=5)
        eng.run()
        return h1.tokens, h2.tokens

    assert run(False) == run(True)


def test_engine_rejects_fused_prefill_without_entry(monkeypatch):
    assert not get_model("zamba2-7b", smoke=True).has_fused_prefill
    # an otherwise engine-capable model without the fused-prefill entry
    from repro.models import rwkv4
    monkeypatch.delattr(rwkv4, "prefill_chunk")
    model = get_model("rwkv4-169m", smoke=True)
    assert not model.has_fused_prefill
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(model, fused_prefill=True)


def test_fused_prefill_capability_flag():
    for arch in ARCHS:
        assert get_model(arch, smoke=True).has_fused_prefill


# ---------------------------------------------------------------------------
# Batched host-side sampling + TTFT telemetry
# ---------------------------------------------------------------------------


def test_sample_tokens_matches_per_row_reference(rng):
    """The batched sampler consumes each slot's RNG stream exactly like
    the per-row reference, and the batched argmax resolves greedy ties
    identically."""
    from repro.serving.scheduler import Request, _Slot, sample_token, \
        sample_tokens
    V, n = 32, 5
    rows = rng.normal(size=(n, V)).astype(np.float32)
    rows[0, 3] = rows[0, 7] = rows[0].max() + 1.0       # greedy tie
    temps = [0.0, 0.9, 0.0, 0.3, 1.7]
    metas = [_Slot(req=Request(rid=i, prompt=[1], temperature=t, seed=i),
                   rng=np.random.default_rng(i)) for i, t in enumerate(temps)]
    got = sample_tokens(rows.copy(), metas)
    ref_rngs = [np.random.default_rng(i) for i in range(n)]
    want = [sample_token(rows[i], temps[i], ref_rngs[i]) for i in range(n)]
    assert list(got) == want
    # streams advanced identically: the NEXT draw matches too
    for m, r in zip(metas, ref_rngs):
        if m.req.temperature > 0:
            assert m.rng.standard_normal() == r.standard_normal()


def test_counters_prefill_ttft_tracking(rwkv4):
    """ServingCounters decomposes TTFT: per-request prefill ticks and
    admit->first-token wall time, with cancelled requests dropped."""
    from repro.runtime.monitor import ServingCounters
    model, params = rwkv4
    t = [0.0]
    clock = lambda: t.__setitem__(0, t[0] + 1.0) or t[0]
    counters = ServingCounters(clock=clock)
    eng = ServingEngine(model, params=params, max_batch=2, prefill_chunk=4,
                        fused_prefill=True, counters=counters)
    eng.submit(list(range(1, 10)), max_new_tokens=2)   # 9 tokens -> 3 ticks
    eng.submit([1, 2], max_new_tokens=2)               # 2 tokens -> 1 tick
    snap = eng.run()
    assert sorted(counters.prefill_ticks) == [1, 3]
    assert len(counters.prefill_s) == 2
    assert all(s > 0 for s in counters.prefill_s)
    assert snap["mean_prefill_ticks"] == 2.0
    assert snap["mean_prefill_s"] > 0
    assert snap["prefill_tokens"] == 11


# ---------------------------------------------------------------------------
# Mixed weight planes through the fused prefill
# ---------------------------------------------------------------------------


def _mixed_policy():
    from repro.core.quant.policy import PlanePolicy
    return PlanePolicy(default="w8", overrides=(
        (r"\['att'\]\['wk'\]", "w4"),
        (r"\['ffn'\]\['wv'\]", "vq"),
        (r"\['head'\]", "w4"),
    ))


def test_chunk_matmul_w4_equals_unpack(rng):
    """`chunk_matmul` on a W4 nibble-packed leaf == the unpack oracle
    exactly: the kernel re-interleaves the nibble pairs with the SAME
    decode as `unpack_leaf`, and the streamed tile is HALF the bytes."""
    from repro.core.quant.serving import unpack_leaf
    from repro.kernels.fused_prefill import chunk_matmul
    from repro.core.quant.delta_pot import FORMAT_W4, dpot_pack_nibbles, \
        dpot_quantize
    w = jnp.asarray(rng.normal(size=(48, 80)), jnp.float32)
    q = dpot_quantize(w, FORMAT_W4, axis=-1)
    leaf = {"packed4": dpot_pack_nibbles(q),
            "scale": q.scale.astype(jnp.float32)}
    assert leaf["packed4"].shape == (24, 80)
    x = jnp.asarray(rng.normal(size=(3, 5, 48)), jnp.bfloat16)
    got = exact_jit(lambda x, l: chunk_matmul(x, l, jnp.bfloat16))(x, leaf)
    want = exact_jit(
        lambda x, l: x @ unpack_leaf(l).astype(jnp.bfloat16))(x, leaf)
    _assert_bitwise(want, got)


def test_chunk_matmul_vq_equals_unpack(rng):
    """`chunk_matmul` on a VQ leaf == the unpack oracle exactly: the
    codebook enters the kernel flattened with a constant index map (one
    resident copy, uint8 indices streamed)."""
    from repro.core.quant.serving import unpack_leaf
    from repro.core.quant.vq import vq_quantize
    from repro.kernels.fused_prefill import chunk_matmul
    w = jnp.asarray(rng.normal(size=(48, 80)), jnp.float32)
    idx, codebook = vq_quantize(w, 64)
    leaf = {"vq_idx": idx, "codebook": codebook}
    x = jnp.asarray(rng.normal(size=(3, 5, 48)), jnp.bfloat16)
    got = exact_jit(lambda x, l: chunk_matmul(x, l, jnp.bfloat16))(x, leaf)
    want = exact_jit(
        lambda x, l: x @ unpack_leaf(l).astype(jnp.bfloat16))(x, leaf)
    _assert_bitwise(want, got)


@pytest.mark.parametrize("arch", ARCHS)
def test_mixed_plane_prefill_bit_parity(arch, rng):
    """Fused chunked prefill over a MIXED-plane tree (W4 wk, VQ ffn.wv,
    W4 head, W8 rest) == the masked per-op scan oracle, bit for bit,
    under prefix masks including an all-invalid lane."""
    model = get_model(arch, smoke=True)
    params = pack_params(model.init_params(jax.random.PRNGKey(0)),
                         _mixed_policy())
    state = _random_state(model, rng)
    tokens = jnp.asarray(rng.integers(0, model.cfg.vocab, (B, C)),
                         jnp.int32)
    valid = _prefix_valid(PREFIX_LENS)
    s1, l1 = exact_jit(lambda p, s: oracle_prefill(
        model, p, s, tokens, valid, quantized=True))(params, state)
    prep = model.prepare_prefill_params(params)
    s2, l2 = exact_jit(lambda p, s: model.prefill_chunk(
        p, s, tokens, valid))(prep, state)
    _assert_bitwise(s1, s2)
    _assert_bitwise(l1, l2)


def _outside_kernel_flat_gather(jaxpr):
    """True if a gather with a 1-D operand (the flattened VQ codebook)
    appears OUTSIDE pallas_call kernels.  The embedding gather is exempt:
    its operand is the 2-D (V, D) table."""
    found = [False]

    def walk(j):
        for eqn in j.eqns:
            if eqn.primitive.name == "pallas_call":
                continue
            if eqn.primitive.name == "gather" and \
                    getattr(eqn.invars[0].aval, "ndim", 0) == 1:
                found[0] = True
            for v in eqn.params.values():
                vals = v if isinstance(v, (list, tuple)) else [v]
                for e in vals:
                    if isinstance(e, jax.core.ClosedJaxpr):
                        walk(e.jaxpr)
                    elif isinstance(e, jax.core.Jaxpr):
                        walk(e)
    walk(jaxpr)
    return found[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_mixed_plane_prefill_never_decodes_in_trace(arch):
    """The bandwidth claim for ALL planes: the mixed-plane fused prefill
    trace contains no weight decode outside a Pallas kernel — no exp2
    (W8/W4 Δ-PoT decode) and no 1-D-operand gather (VQ codebook lookup)
    outside pallas_call; the uint8 planes are consumed by kernels
    directly.  The per-op oracle trips both detectors."""
    model = get_model(arch, smoke=True)
    packed = pack_params(model.init_params(jax.random.PRNGKey(0)),
                         _mixed_policy())
    prep = model.prepare_prefill_params(packed)
    state = model.init_decode_state(B, 0, jnp.bfloat16)
    tokens = jnp.zeros((B, C), jnp.int32)
    valid = jnp.ones((B, C), bool)
    jx = jax.make_jaxpr(lambda p, s: model.prefill_chunk(
        p, s, tokens, valid))(prep, state)
    outside = _outside_kernel_primitives(jx.jaxpr, set())
    assert "exp2" not in outside, (
        "Δ-PoT decode leaked out of the kernels into the prefill trace")
    assert not _outside_kernel_flat_gather(jx.jaxpr), (
        "VQ codebook gather leaked out of the kernels")
    assert _pallas_consumes_uint8(jx.jaxpr)
    # detector sanity: the per-op oracle decodes in-trace
    jx_oracle = jax.make_jaxpr(lambda p, s: oracle_prefill(
        model, p, s, tokens, valid, quantized=True))(packed, state)
    assert "exp2" in _outside_kernel_primitives(jx_oracle.jaxpr, set())
    assert _outside_kernel_flat_gather(jx_oracle.jaxpr)
