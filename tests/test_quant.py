"""Quantization library tests: Δ-PoT (paper C1), uniform (C2), baselines,
and the mixed-precision policy — including hypothesis property tests."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: property tests importorskip at run time
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.core.quant.delta_pot import (
    DPotFormat, FORMAT_W9, FORMAT_W8, FORMAT_POT4, dpot_levels,
    dpot_max_level, dpot_quantize, dpot_dequantize, dpot_fake_quant,
    dpot_pack_int8, dpot_unpack_int8, dpot_decode_codes)
from repro.core.quant.uniform import (
    uniform_quantize, uniform_dequantize, uniform_fake_quant)
from repro.core.quant.schemes import (
    rtn_fake_quant, pot_fake_quant, logq_fake_quant, proposed_fake_quant)
from repro.core.quant.policy import (
    QuantPolicy, classify_param, fake_quantize_tree, quantize_tree,
    dequantize_tree)


# ---------------------------------------------------------------------------
# Δ-PoT format / levels
# ---------------------------------------------------------------------------


class TestDPotLevels:
    def test_paper_example(self):
        """§3.1: Δ-PoT with ks=(2,2) can represent 2^-1 + 2^-3 exactly."""
        lv = np.asarray(dpot_levels(DPotFormat((2, 2))))
        assert np.any(np.isclose(lv, 0.5 + 0.125))

    def test_zero_code_is_zero(self):
        for fmt in (FORMAT_W9, FORMAT_W8, FORMAT_POT4):
            assert float(dpot_levels(fmt)[0]) == 0.0

    def test_terms_decreasing(self):
        """Every level is a sum of strictly decreasing PoT terms => every
        level is < 2 * first term <= 1."""
        for fmt in (FORMAT_W9, FORMAT_W8):
            lv = np.asarray(dpot_levels(fmt))
            assert lv.max() <= 1.0
            assert lv.min() >= 0.0

    def test_pot_degenerate(self):
        """Single-term Δ-PoT == classic PoT grid {0} ∪ {2^-q}."""
        lv = sorted(set(np.asarray(dpot_levels(FORMAT_POT4)).tolist()))
        expect = [0.0] + [2.0 ** (-q) for q in range(15, 0, -1)]
        assert np.allclose(lv, expect)

    def test_wider_range_than_apot_equal_bits(self):
        """Differential encoding covers exponents down to 2^-(2^k0-1 + 2^k1-1),
        deeper than APoT's fixed stride at the same bit budget."""
        lv = np.asarray(dpot_levels(DPotFormat((4, 4))))
        nz = lv[lv > 0]
        assert nz.min() <= 2.0 ** -15


class TestDPotQuantize:
    def test_roundtrip_exact_levels(self, rng):
        """Values that ARE representable levels must roundtrip exactly."""
        fmt = FORMAT_W9
        lv = np.asarray(dpot_levels(fmt))
        scale = 1.7
        # well-separated levels (the deepest ones differ at f32 epsilon and
        # legitimately round to neighbours)
        vals = np.unique(lv[lv >= 2.0 ** -8]) * scale
        w = jnp.asarray(np.concatenate([vals, -vals]))
        q = dpot_quantize(w, fmt, axis=None)
        # scale covers max|w|; nearest-level must land on exact values
        got = np.asarray(dpot_dequantize(q))
        np.testing.assert_allclose(got, np.asarray(w), rtol=1e-6)

    def test_error_bounded_by_half_gap(self, rng):
        fmt = FORMAT_W9
        w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        q = dpot_quantize(w, fmt, axis=1)
        err = np.abs(np.asarray(dpot_dequantize(q)) - np.asarray(w))
        # per-channel worst error <= half the largest level gap * scale
        lv = np.sort(np.unique(np.asarray(dpot_levels(fmt))))
        max_gap = np.max(np.diff(lv))
        scale = np.asarray(q.scale)
        assert np.all(err <= 0.5 * max_gap * scale + 1e-6)

    def test_per_channel_scales(self, rng):
        w = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W9, axis=1)
        assert q.scale.shape == (1, 32)

    def test_mse_search_not_worse(self, rng):
        w = jnp.asarray(rng.standard_t(3, size=(256,)), jnp.float32)
        base = dpot_fake_quant(w, (4, 4), None, False)
        ref = dpot_fake_quant(w, (4, 4), None, True)
        e0 = float(jnp.mean((base - w) ** 2))
        e1 = float(jnp.mean((ref - w) ** 2))
        assert e1 <= e0 * 1.0001

    def test_straight_through_gradient(self):
        g = jax.grad(lambda w: jnp.sum(dpot_fake_quant(w, (4, 4), None,
                                                       False)))(
            jnp.ones((4, 4)))
        np.testing.assert_allclose(np.asarray(g), 1.0)

    @given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_decode_matches_table(self, k0, k1, seed):
        """Property: vectorized decoder == enumerated level table."""
        fmt = DPotFormat((k0, k1))
        rng = np.random.default_rng(seed)
        codes = rng.integers(0, fmt.n_codes, size=(32,)).astype(np.uint8)
        got = np.asarray(dpot_decode_codes(jnp.asarray(codes), fmt.ks))
        want = np.asarray(dpot_levels(fmt))[codes]
        np.testing.assert_allclose(got, want, rtol=1e-6)

    @given(st.integers(0, 10_000))
    @settings(max_examples=25, deadline=None)
    def test_quantize_idempotent(self, seed):
        """Property: fake-quant is idempotent (q(q(x)) == q(x))."""
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        q1 = dpot_fake_quant(w, (4, 4), None, False)
        q2 = dpot_fake_quant(q1, (4, 4), None, False)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                                   rtol=1e-5, atol=1e-7)

    def test_pack_unpack_int8(self, rng):
        w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W8, axis=1)
        packed = dpot_pack_int8(q)
        q2 = dpot_unpack_int8(packed, q.scale, FORMAT_W8.ks)
        np.testing.assert_array_equal(np.asarray(q.codes),
                                      np.asarray(q2.codes))
        np.testing.assert_array_equal(np.asarray(q.signs),
                                      np.asarray(q2.signs))

    def test_pack_rejects_w9(self, rng):
        q = dpot_quantize(jnp.ones((4, 4)), FORMAT_W9)
        with pytest.raises(ValueError):
            dpot_pack_int8(q)

    def test_bytes_accounting(self):
        q = dpot_quantize(jnp.ones((128, 128)), FORMAT_W8, axis=1)
        nb = q.nbytes_hardware()
        assert nb == 128 * 128 * 8 // 8 + 128 * 4


# ---------------------------------------------------------------------------
# Uniform + baseline schemes
# ---------------------------------------------------------------------------


class TestUniform:
    def test_symmetric_grid(self):
        codes, scale = uniform_quantize(jnp.asarray([-1.0, 0.0, 1.0]), 9)
        assert int(codes[0]) == -255 and int(codes[2]) == 255

    @given(st.integers(2, 12), st.integers(0, 10_000))
    @settings(max_examples=30, deadline=None)
    def test_error_bound(self, bits, seed):
        """Property: uniform quant error <= scale/2 everywhere."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
        codes, scale = uniform_quantize(x, bits)
        err = np.abs(np.asarray(uniform_dequantize(codes, scale) - x))
        assert np.all(err <= float(scale) / 2 + 1e-7)

    def test_fake_quant_gradient(self):
        g = jax.grad(lambda x: jnp.sum(uniform_fake_quant(x, 9, None)))(
            jnp.linspace(-1, 1, 16))
        np.testing.assert_allclose(np.asarray(g), 1.0)


class TestSchemeOrdering:
    def test_proposed_beats_pot_and_logq(self, rng):
        """The paper's Table-1 ordering on weight-space MSE: Δ-PoT < LogQ,
        PoT (heavier-tailed weights accentuate the gap)."""
        w = jnp.asarray(rng.standard_t(4, size=(512, 64)), jnp.float32)
        def mse(f):
            return float(jnp.mean((f(w, 9, 1) - w) ** 2))
        e_prop = mse(proposed_fake_quant)
        e_pot = mse(pot_fake_quant)
        e_logq = mse(logq_fake_quant)
        assert e_prop < e_pot
        assert e_prop < e_logq


# ---------------------------------------------------------------------------
# Mixed-precision policy (paper §3.2)
# ---------------------------------------------------------------------------


class TestPolicy:
    def test_classification(self):
        assert classify_param("['blocks']['att']['wk']",
                              jnp.ones((8, 8))) == "matmul"
        assert classify_param("['blocks']['att']['time_decay']",
                              jnp.ones((8,))) == "additive"
        assert classify_param("['embed']", jnp.ones((100, 8))) == "additive"
        assert classify_param("['ln0']['scale']",
                              jnp.ones((8,))) == "additive"

    def test_tree_roundtrip_and_compression(self, rng):
        params = {
            "embed": jnp.asarray(rng.normal(size=(64, 16)), jnp.float32),
            "blocks": {"wk": jnp.asarray(rng.normal(size=(16, 16)),
                                         jnp.float32),
                       "time_decay": jnp.asarray(rng.normal(size=(16,)),
                                                 jnp.float32)},
        }
        qt, stats = quantize_tree(params, QuantPolicy())
        assert stats["compression"] > 1.5
        deq = dequantize_tree(qt)
        for k in ("embed",):
            err = np.abs(np.asarray(deq[k]) - np.asarray(params[k]))
            assert err.max() < 0.1 * np.abs(np.asarray(params[k])).max()

    def test_fake_quant_preserves_structure(self, rng):
        params = {"a": jnp.ones((4, 4)), "b": {"scale": jnp.ones((4,))}}
        out = fake_quantize_tree(params)
        assert jax.tree_util.tree_structure(out) == \
            jax.tree_util.tree_structure(params)


# ---------------------------------------------------------------------------
# W4 nibble packing (FORMAT_W4: sign + 3-bit single-term codes, 2/byte)
# ---------------------------------------------------------------------------


class TestNibblePacking:
    def _quantized(self, rng, shape=(48, 32)):
        from repro.core.quant.delta_pot import FORMAT_W4
        w = jnp.asarray(rng.normal(size=shape), jnp.float32)
        return dpot_quantize(w, FORMAT_W4, axis=-1)

    def test_roundtrip_bitwise(self, rng):
        """pack -> unpack reproduces codes, signs AND dequantized values
        bit for bit — the property the in-kernel decode relies on."""
        from repro.core.quant.delta_pot import (FORMAT_W4,
                                                dpot_pack_nibbles,
                                                dpot_unpack_nibbles)
        q = self._quantized(rng)
        packed = dpot_pack_nibbles(q)
        assert packed.dtype == jnp.uint8
        assert packed.shape == (24, 32)          # HALF the rows
        q2 = dpot_unpack_nibbles(packed, q.scale, FORMAT_W4.ks)
        np.testing.assert_array_equal(np.asarray(q.codes),
                                      np.asarray(q2.codes))
        np.testing.assert_array_equal(np.asarray(q.signs),
                                      np.asarray(q2.signs))
        np.testing.assert_array_equal(
            np.asarray(dpot_dequantize(q), np.float32),
            np.asarray(dpot_dequantize(q2), np.float32))

    def test_stacked_leading_axes(self, rng):
        """(L, K, N) stacked leaves pack along axis -2 per layer — the
        megakernel slab form."""
        from repro.core.quant.delta_pot import (FORMAT_W4,
                                                dpot_pack_nibbles,
                                                dpot_unpack_nibbles)
        q = self._quantized(rng, shape=(3, 8, 16))
        packed = dpot_pack_nibbles(q)
        assert packed.shape == (3, 4, 16)
        q2 = dpot_unpack_nibbles(packed, q.scale, FORMAT_W4.ks)
        np.testing.assert_array_equal(np.asarray(q.codes),
                                      np.asarray(q2.codes))

    def test_rejects_wide_formats(self, rng):
        """Only formats with <= 3 code bits fit a nibble beside the sign."""
        from repro.core.quant.delta_pot import dpot_pack_nibbles
        w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W8, axis=-1)
        with pytest.raises(ValueError):
            dpot_pack_nibbles(q)

    def test_rejects_odd_contraction_axis(self, rng):
        from repro.core.quant.delta_pot import FORMAT_W4, dpot_pack_nibbles
        w = jnp.asarray(rng.normal(size=(7, 8)), jnp.float32)
        with pytest.raises(ValueError):
            dpot_pack_nibbles(dpot_quantize(w, FORMAT_W4, axis=-1))

    def test_w4_levels_single_term_pot(self):
        """FORMAT_W4's level grid is {0} ∪ {2^-1..2^-7}: the degenerate
        single-term Δ-PoT the 3-bit code can address."""
        from repro.core.quant.delta_pot import FORMAT_W4
        lv = sorted(set(np.asarray(dpot_levels(FORMAT_W4)).tolist()))
        np.testing.assert_allclose(
            lv, [0.0] + [2.0 ** (-q) for q in range(7, 0, -1)])


# ---------------------------------------------------------------------------
# VQ codebook plane (per-tensor 1-D k-means, uint8 indices)
# ---------------------------------------------------------------------------


class TestVQ:
    def test_exact_codebook_roundtrips(self, rng):
        """Weights drawn from <= n_codes distinct values reconstruct to
        those values exactly (mod bf16 rounding of the centroids)."""
        from repro.core.quant.vq import vq_dequantize, vq_quantize
        lv = np.asarray([-1.0, -0.25, 0.0, 0.5, 1.5], np.float32)
        w = jnp.asarray(lv[rng.integers(0, len(lv), size=(32, 16))])
        idx, cb = vq_quantize(w, 16)
        got = np.asarray(vq_dequantize(idx, cb), np.float32)
        np.testing.assert_array_equal(
            got, np.asarray(jnp.asarray(w).astype(jnp.bfloat16), np.float32))

    def test_forms(self, rng):
        from repro.core.quant.vq import vq_quantize
        w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
        idx, cb = vq_quantize(w, 256)
        assert idx.dtype == jnp.uint8 and idx.shape == w.shape
        assert cb.dtype == jnp.bfloat16 and cb.shape == (1, 256)

    def test_deterministic(self, rng):
        from repro.core.quant.vq import vq_quantize
        w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
        i1, c1 = vq_quantize(w, 32)
        i2, c2 = vq_quantize(w, 32)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(c1, np.float32),
                                      np.asarray(c2, np.float32))

    def test_assignment_is_nearest(self, rng):
        """Every weight maps to its NEAREST stored (bf16) centroid — the
        assignment optimizes the codebook that actually ships."""
        from repro.core.quant.vq import vq_dequantize, vq_quantize
        w = jnp.asarray(rng.normal(size=(512,)), jnp.float32)
        idx, cb = vq_quantize(w, 16)
        got = np.asarray(vq_dequantize(idx, cb), np.float32)
        centers = np.asarray(cb, np.float32).reshape(-1)
        best = np.abs(w[:, None] - centers[None, :]).min(1)
        np.testing.assert_allclose(np.abs(np.asarray(w) - got), best,
                                   atol=1e-6)

    def test_kmeans_reduces_error_vs_quantiles(self, rng):
        from repro.core.quant.vq import kmeans_1d
        v = np.asarray(rng.standard_t(3, size=4096), np.float32)
        c16 = np.asarray(kmeans_1d(jnp.asarray(v), 16), np.float32)
        c4 = np.asarray(kmeans_1d(jnp.asarray(v), 4), np.float32)
        e16 = np.abs(v[:, None] - c16[None]).min(1).mean()
        e4 = np.abs(v[:, None] - c4[None]).min(1).mean()
        assert e16 < e4


# ---------------------------------------------------------------------------
# PlanePolicy: per-tensor plane selection + fingerprints
# ---------------------------------------------------------------------------


class TestPlanePolicy:
    def test_proxy_separates_tails(self, rng):
        from repro.core.quant.policy import weight_outlier_proxy
        gauss = rng.normal(size=(256, 256)).astype(np.float32)
        heavy = rng.standard_t(3, size=(256, 256)).astype(np.float32)
        assert weight_outlier_proxy(gauss) < 1.0
        assert weight_outlier_proxy(heavy) > 8.0

    def test_proxy_thresholds_route_planes(self, rng):
        from repro.core.quant.policy import PLANE_PROXY
        gauss = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
        heavy = jnp.asarray(rng.standard_t(3, size=(64, 64)), jnp.float32)
        assert PLANE_PROXY.plane_for("['x']", gauss) == "w4"
        assert PLANE_PROXY.plane_for("['x']", heavy) == "vq"

    def test_overrides_win(self, rng):
        from repro.core.quant.policy import PlanePolicy
        pol = PlanePolicy(default="w8", overrides=((r"wk", "vq"),))
        w = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        assert pol.plane_for("['att']['wk']", w) == "vq"
        assert pol.plane_for("['att']['wv']", w) == "w8"

    def test_invalid_rejected(self):
        from repro.core.quant.policy import PlanePolicy
        with pytest.raises(ValueError):
            PlanePolicy(default="w3")
        with pytest.raises(ValueError):
            PlanePolicy(overrides=(("wk", "int4"),))

    def test_config_roundtrip(self):
        from repro.core.quant.policy import PlanePolicy
        pol = PlanePolicy(default="proxy", w4_max_proxy=2.0,
                          overrides=((r"head", "w4"),))
        assert PlanePolicy.from_config(pol.to_config()) == pol
        assert PlanePolicy.from_config(None) is None

    def test_pack_params_w4_odd_axis_falls_back_to_w8(self, rng):
        from repro.core.quant.policy import PLANE_W4
        from repro.core.quant.serving import leaf_plane, pack_params
        tree = {"att": {"wk": jnp.asarray(rng.normal(size=(47, 8)),
                                          jnp.float32)}}
        packed = pack_params(tree, PLANE_W4)
        assert leaf_plane(packed["att"]["wk"]) == "w8"

    def test_unpack_leaf_matches_reference_per_plane(self, rng):
        """`unpack_leaf` (the single decode source of truth) reproduces
        each plane's reference dequantization bitwise."""
        from repro.core.quant.delta_pot import FORMAT_W4, dpot_pack_nibbles
        from repro.core.quant.serving import unpack_leaf
        from repro.core.quant.vq import vq_dequantize, vq_quantize
        w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        q = dpot_quantize(w, FORMAT_W4, axis=-1)
        leaf = {"packed4": dpot_pack_nibbles(q),
                "scale": q.scale.astype(jnp.float32)}
        np.testing.assert_array_equal(
            np.asarray(unpack_leaf(leaf), np.float32),
            np.asarray(dpot_dequantize(q).astype(jnp.bfloat16), np.float32))
        idx, cb = vq_quantize(w, 32)
        np.testing.assert_array_equal(
            np.asarray(unpack_leaf({"vq_idx": idx, "codebook": cb}),
                       np.float32),
            np.asarray(vq_dequantize(idx, cb).astype(jnp.bfloat16),
                       np.float32))

    def test_quantize_tree_plane_stats(self, rng):
        from repro.core.quant.policy import PlanePolicy
        tree = {"att": {"wk": jnp.asarray(rng.normal(size=(16, 16)),
                                          jnp.float32),
                        "wv": jnp.asarray(rng.normal(size=(16, 16)),
                                          jnp.float32)}}
        pol = PlanePolicy(default="w4", overrides=((r"wv", "vq"),))
        _, stats = quantize_tree(tree, planes=pol)
        assert stats["planes"]["['att']['wk']"] == "w4"
        assert stats["planes"]["['att']['wv']"] == "vq"
        assert set(stats["bytes_by_plane"]) == {"w4", "vq"}
        # W4 stores half the code bytes of W8 for the same tensor
        assert stats["bytes_by_plane"]["w4"] < 16 * 16


class TestPlaneFingerprint:
    def test_historical_strings(self, rng):
        """fp trees and all-W8 packs keep the exact historical CacheVariant
        strings, so pre-plane cache entries and snapshots stay valid."""
        from repro.core.quant.serving import pack_params, plane_fingerprint
        tree = {"head": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
        assert plane_fingerprint(tree) == "fp"
        assert plane_fingerprint(pack_params(tree)) == "dpot_w8"

    def test_mixes_hash_and_never_alias(self, rng):
        from repro.core.quant.policy import PLANE_VQ, PLANE_W4
        from repro.core.quant.serving import pack_params, plane_fingerprint
        tree = {"a": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32),
                "b": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
        f_w4 = plane_fingerprint(pack_params(tree, PLANE_W4))
        f_vq = plane_fingerprint(pack_params(tree, PLANE_VQ))
        assert f_w4.startswith("dpot_mix_")
        assert f_vq.startswith("dpot_mix_")
        assert f_w4 != f_vq
        # deterministic: same policy, same fingerprint
        assert f_w4 == plane_fingerprint(pack_params(tree, PLANE_W4))
