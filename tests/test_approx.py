"""Complex-operation unit tests (paper §4.3-4.4): error bounds of the
bit-accurate LUT/PWL models against true functions."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:   # optional dep: property tests importorskip at run time
    from conftest import hypothesis_stubs
    given, settings, st = hypothesis_stubs()

from repro.core.approx import exp_lut, sigmoid_pwl, div_lut, lod


class TestExpLut:
    def test_relative_error_in_wkv_range(self):
        """8-bit fraction LUT + hw log2e constant: the dominant error is the
        1.4375 vs 1.442695 constant (paper's hardware uses exactly 1.0111_2);
        relative error grows as |x| * 0.36%."""
        x = jnp.linspace(-8.0, 8.0, 2001)
        got = np.asarray(exp_lut(x))
        want = np.exp(np.asarray(x))
        rel = np.abs(got - want) / want
        bound = np.abs(np.asarray(x)) * 0.0037 + 0.006
        assert np.all(rel <= bound)

    def test_monotone_nondecreasing(self):
        x = jnp.linspace(-20.0, 20.0, 4001)
        y = np.asarray(exp_lut(x))
        assert np.all(np.diff(y) >= -1e-6)

    def test_clamps_not_nan(self):
        y = np.asarray(exp_lut(jnp.asarray([-1e9, 1e9])))
        assert np.all(np.isfinite(y))


class TestSigmoidPwl:
    def test_max_abs_error(self):
        """4-segment PWL (Eq. 9) has a known worst-case error ~2.45e-2."""
        x = jnp.linspace(-10, 10, 10001)
        err = np.abs(np.asarray(sigmoid_pwl(x)) -
                     1 / (1 + np.exp(-np.asarray(x))))
        assert err.max() < 0.025

    def test_symmetry(self):
        """f(-x) = 1 - f(x) exactly (the paper's mirror rule)."""
        x = jnp.linspace(0, 6, 100)
        a = np.asarray(sigmoid_pwl(x))
        b = np.asarray(sigmoid_pwl(-x))
        np.testing.assert_allclose(a + b, 1.0, atol=1e-6)

    def test_saturation(self):
        assert float(sigmoid_pwl(jnp.asarray(5.0))) == 1.0
        assert float(sigmoid_pwl(jnp.asarray(-5.0))) == 0.0


class TestDivLut:
    @given(st.integers(0, 10_000))
    @settings(max_examples=40, deadline=None)
    def test_relative_error(self, seed):
        """4+4-bit mantissa indexing -> worst-case relative error ~2^-4·0.5
        on each mantissa plus LUT rounding: bound 8%."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.uniform(-100, 100, size=(64,)), jnp.float32)
        y = jnp.asarray(rng.uniform(0.1, 100, size=(64,)), jnp.float32)
        got = np.asarray(div_lut(x, y))
        want = np.asarray(x) / np.asarray(y)
        rel = np.abs(got - want) / np.maximum(np.abs(want), 1e-6)
        assert np.all(rel < 0.08)

    def test_sign_handling(self):
        assert float(div_lut(jnp.asarray(-1.0), jnp.asarray(2.0))) < 0
        assert float(div_lut(jnp.asarray(-1.0), jnp.asarray(-2.0))) > 0

    def test_div_by_zero_saturates(self):
        q = float(div_lut(jnp.asarray(1.0), jnp.asarray(0.0)))
        assert q == 2.0 ** 15

    def test_zero_numerator(self):
        assert float(div_lut(jnp.asarray(0.0), jnp.asarray(3.0))) == 0.0


class TestLod:
    @given(st.integers(1, (1 << 16) - 1))
    @settings(max_examples=50, deadline=None)
    def test_matches_bit_length(self, v):
        assert int(lod(jnp.asarray([v]), 16)[0]) == v.bit_length() - 1

    def test_zero_returns_minus1(self):
        assert int(lod(jnp.asarray([0]), 16)[0]) == -1
